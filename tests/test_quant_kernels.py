"""Quantized merged-kernel certification (this PR's tentpole).

Every quantized execution path — int8 weights (w8a16), int8
weights+activations (w8a8), and the fp8 scaffolding — runs the Pallas
kernels in interpret mode on CPU and is held to TWO references:

* the *quantized* jnp oracle (``*_qref``: dequantized-weight math) with a
  tight tolerance — certifies the kernel computes exactly the dequantized
  arithmetic it claims (post-accumulation per-channel scaling included);
* the *fp32* oracle within the RIGOROUS worst-case error budget of
  :func:`repro.kernels.quant.error_budget` — bounds, not tuned
  tolerances, so a quantization-semantics regression cannot hide inside a
  loose comparison.

Plus the shared primitive's contract: per-tensor mode bit-identical to
the historical ``optim.compress`` helpers (which now re-export it), and
per-channel round-trip error ≤ scale/2 elementwise.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro import kernels
from repro.kernels import quant

QTOL = dict(rtol=2e-4, atol=2e-4)      # kernel vs dequantized-math oracle


def _pad(x, K):
    lo = (K - 1) // 2
    hi = K - 1 - lo
    return jnp.pad(x, ((0, 0), (lo, hi), (lo, hi), (0, 0))) if K > 1 else x


def _conv_budget(mode, x, w, fan_in):
    return quant.error_budget(mode, fan_in=fan_in,
                              x_absmax=float(jnp.max(jnp.abs(x))),
                              w_absmax=float(jnp.max(jnp.abs(w))))


# ---------------------------------------------------------------------------
# shared primitive
# ---------------------------------------------------------------------------

def test_per_tensor_matches_optim_helpers():
    """optim.compress re-exports THE shared primitive (satellite: one
    rounding semantics repo-wide)."""
    from repro.optim import compress as oc
    assert oc.quantize_int8 is quant.quantize_int8
    assert oc.dequantize_int8 is quant.dequantize_int8


@given(seed=st.integers(0, 10_000), axis=st.sampled_from([None, 0, 1, -1]),
       scale=st.floats(1e-3, 1e3))
@settings(max_examples=24, deadline=None)
def test_int8_roundtrip_halfstep(seed, axis, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((5, 7)) * scale, jnp.float32)
    q, s = quant.quantize_int8(x, axis=axis)
    assert q.dtype == jnp.int8
    if axis is not None:
        assert s.shape == (x.shape[axis],)
    y = quant.dequantize_int8(q, s, axis=axis)
    step = np.asarray(s) if axis is None else \
        np.expand_dims(np.asarray(s),
                       [i for i in range(x.ndim) if i != axis % x.ndim])
    assert np.all(np.abs(np.asarray(x - y)) <= step / 2 + 1e-12)


def test_fp8_roundtrip_relative():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
    q, s = quant.quantize_fp8(x, axis=1)
    assert q.dtype == jnp.float8_e4m3fn
    y = quant.dequantize(q, s, axis=1)
    # e4m3 half-ulp: 2^-4 relative, after the per-channel rescale
    err = np.abs(np.asarray(x - y))
    bound = np.abs(np.asarray(x)) * 2.0 ** -4 + np.asarray(s)[None, :]
    assert np.all(err <= bound)


def test_error_budget_monotone_and_zero_for_fp():
    assert quant.error_budget("none", fan_in=9, x_absmax=1., w_absmax=1.) == 0
    b_int8 = quant.error_budget("int8", fan_in=9, x_absmax=1., w_absmax=1.)
    b_w8a8 = quant.error_budget("w8a8", fan_in=9, x_absmax=1., w_absmax=1.)
    assert 0 < b_int8 < b_w8a8


# ---------------------------------------------------------------------------
# dense merged conv
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["int8", "w8a8", "fp8"])
@pytest.mark.parametrize("stride", [1, 2])
def test_merged_conv_quant_matrix(mode, stride):
    rng = np.random.default_rng(hash((mode, stride)) % 2**31)
    k, cin, cout = 3, 5, 13
    x = jnp.asarray(rng.standard_normal((2, 12, 12, cin)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, k, cin, cout)) * .3, jnp.float32)
    b = jnp.asarray(rng.standard_normal(cout), jnp.float32)
    wq, ws = quant.quantize_weight(w, mode, axis=3)
    xp = _pad(x, k)
    aq = mode if mode == "w8a8" else "none"
    y = kernels.merged_conv_op(xp, wq, b, stride=stride, w_scale=ws,
                               act_quant=aq, interpret=True)
    yq = kernels.merged_conv_qref(xp, wq, b, ws, stride=stride, act_quant=aq)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yq), **QTOL)
    yf = kernels.merged_conv_ref(xp, w, b, stride=stride)
    budget = _conv_budget(mode, x, w, fan_in=k * k * cin)
    maxdiff = float(jnp.max(jnp.abs(y - yf)))
    assert maxdiff <= budget, (maxdiff, budget)


@given(stride=st.integers(1, 2), k=st.sampled_from([1, 3, 5]),
       cin=st.integers(2, 9), cout=st.integers(3, 17),
       h=st.integers(8, 14), mode=st.sampled_from(["int8", "w8a8", "fp8"]))
@settings(max_examples=20, deadline=None)
def test_merged_conv_quant_sweep(stride, k, cin, cout, h, mode):
    rng = np.random.default_rng(hash((stride, k, cin, cout, h, mode))
                                % 2**31)
    x = jnp.asarray(rng.standard_normal((1, h, h, cin)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, k, cin, cout)) * .2, jnp.float32)
    wq, ws = quant.quantize_weight(w, mode, axis=3)
    xp = _pad(x, k)
    aq = mode if mode == "w8a8" else "none"
    y = kernels.merged_conv_op(xp, wq, None, stride=stride, w_scale=ws,
                               act_quant=aq, interpret=True)
    yq = kernels.merged_conv_qref(xp, wq, None, ws, stride=stride,
                                  act_quant=aq)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yq), **QTOL)
    yf = kernels.merged_conv_ref(xp, w, None, stride=stride)
    assert float(jnp.max(jnp.abs(y - yf))) <= \
        _conv_budget(mode, x, w, fan_in=k * k * cin)


def test_merged_conv_quant_no_oracle_fallback():
    """Quantized convs must route through pl.pallas_call when the backend
    is forced — the fast path exists, not just the qref."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((1, 10, 10, 4)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 4, 8)) * .2, jnp.float32)
    wq, ws = quant.quantize_weight(w, "int8", axis=3)
    xp = _pad(x, 3)
    with kernels.force_backend("pallas"):
        y = kernels.merged_conv_op(xp, wq, None, w_scale=ws, interpret=True)
    yq = kernels.merged_conv_qref(xp, wq, None, ws)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yq), **QTOL)


# ---------------------------------------------------------------------------
# depthwise / grouped merged conv
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["int8", "w8a8"])
@pytest.mark.parametrize("stride", [1, 2])
def test_depthwise_quant_matrix(mode, stride):
    rng = np.random.default_rng(hash((mode, stride, "dw")) % 2**31)
    k, c = 3, 13                        # C not a multiple of 8: padding path
    x = jnp.asarray(rng.standard_normal((2, 11, 11, c)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, k, 1, c)) * .3, jnp.float32)
    b = jnp.asarray(rng.standard_normal(c), jnp.float32)
    wq, ws = quant.quantize_weight(w, mode, axis=3)
    xp = _pad(x, k)
    aq = mode if mode == "w8a8" else "none"
    y = kernels.depthwise_conv_op(xp, wq, b, stride=stride, w_scale=ws,
                                  act_quant=aq, interpret=True)
    yq = kernels.depthwise_conv_qref(xp, wq, b, ws, stride=stride,
                                     act_quant=aq)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yq), **QTOL)
    yf = kernels.depthwise_conv_ref(xp, w, b, stride=stride)
    assert float(jnp.max(jnp.abs(y - yf))) <= \
        _conv_budget(mode, x, w, fan_in=k * k)       # depthwise fan-in


@given(stride=st.integers(1, 2), k=st.sampled_from([1, 3, 5]),
       groups=st.integers(2, 6), cin_g=st.integers(1, 3),
       mode=st.sampled_from(["int8", "w8a8"]))
@settings(max_examples=16, deadline=None)
def test_grouped_quant_sweep(stride, k, groups, cin_g, mode):
    rng = np.random.default_rng(hash((stride, k, groups, cin_g, mode))
                                % 2**31)
    cin, cout = groups * cin_g, groups * 2
    x = jnp.asarray(rng.standard_normal((1, 10, 10, cin)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, k, cin_g, cout)) * .2,
                    jnp.float32)
    wq, ws = quant.quantize_weight(w, mode, axis=3)
    xp = _pad(x, k)
    aq = mode if mode == "w8a8" else "none"
    y = kernels.depthwise_conv_op(xp, wq, None, stride=stride, groups=groups,
                                  w_scale=ws, act_quant=aq, interpret=True)
    yq = kernels.depthwise_conv_qref(xp, wq, None, ws, stride=stride,
                                     groups=groups, act_quant=aq)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yq), **QTOL)
    yf = kernels.depthwise_conv_ref(xp, w, None, stride=stride,
                                    groups=groups)
    assert float(jnp.max(jnp.abs(y - yf))) <= \
        _conv_budget(mode, x, w, fan_in=k * k * cin_g)


# ---------------------------------------------------------------------------
# merged rank-r FFN
# ---------------------------------------------------------------------------

def _ffn_budget(mode, x, u, v):
    """Two-stage worst case: stage-1 budget propagates through |V|."""
    d, r = u.shape
    xm = float(jnp.max(jnp.abs(x)))
    um = float(jnp.max(jnp.abs(u)))
    vm = float(jnp.max(jnp.abs(v)))
    b1 = quant.error_budget(mode, fan_in=d, x_absmax=xm, w_absmax=um)
    hm = float(jnp.max(jnp.abs(x @ u))) + b1
    # dequantized V entries exceed |V|max by at most half a scale step
    vm_q = vm * (1.0 + 1.0 / quant.INT8_QMAX)
    b2 = quant.error_budget(mode, fan_in=r, x_absmax=hm, w_absmax=vm)
    return b2 + b1 * r * vm_q


@pytest.mark.parametrize("mode", ["int8", "w8a8", "fp8"])
def test_merged_ffn_quant(mode):
    rng = np.random.default_rng(hash((mode, "ffn")) % 2**31)
    d, r, tok = 24, 10, 9
    x = jnp.asarray(rng.standard_normal((2, tok, d)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((d, r)) * .3, jnp.float32)
    v = jnp.asarray(rng.standard_normal((r, d)) * .3, jnp.float32)
    uq, us = quant.quantize_weight(u, mode, axis=1)
    vq, vs = quant.quantize_weight(v, mode, axis=1)
    aq = mode if mode == "w8a8" else "none"
    y = kernels.merged_ffn_op(x, uq, vq, u_scale=us, v_scale=vs,
                              act_quant=aq, interpret=True)
    yq = kernels.merged_ffn_qref(x, uq, vq, us, vs, act_quant=aq)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yq), **QTOL)
    yf = kernels.merged_ffn_ref(x, u, v)
    maxdiff = float(jnp.max(jnp.abs(y - yf)))
    budget = _ffn_budget(mode, x.reshape(-1, d), u, v)
    assert maxdiff <= budget, (maxdiff, budget)


@given(d=st.integers(8, 40), r=st.integers(2, 16), tok=st.integers(1, 12),
       mode=st.sampled_from(["int8", "w8a8"]))
@settings(max_examples=16, deadline=None)
def test_merged_ffn_quant_sweep(d, r, tok, mode):
    rng = np.random.default_rng(hash((d, r, tok, mode)) % 2**31)
    x = jnp.asarray(rng.standard_normal((1, tok, d)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((d, r)) * .2, jnp.float32)
    v = jnp.asarray(rng.standard_normal((r, d)) * .2, jnp.float32)
    uq, us = quant.quantize_weight(u, mode, axis=1)
    vq, vs = quant.quantize_weight(v, mode, axis=1)
    aq = mode if mode == "w8a8" else "none"
    y = kernels.merged_ffn_op(x, uq, vq, u_scale=us, v_scale=vs,
                              act_quant=aq, interpret=True)
    yq = kernels.merged_ffn_qref(x, uq, vq, us, vs, act_quant=aq)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yq), **QTOL)
    yf = kernels.merged_ffn_ref(x, u, v)
    assert float(jnp.max(jnp.abs(y - yf))) <= \
        _ffn_budget(mode, x.reshape(-1, d), u, v)
