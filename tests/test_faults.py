"""Crash-safety certification: the deterministic fault-injection suite.

Every recovery path the pipeline claims is exercised here under
:mod:`repro.testing.faults`:

* journal primitives survive torn appends and self-heal the file;
* a table build killed mid-bucket / mid-journal-write / mid-publish
  resumes **bit-identical** to an uninterrupted build;
* flaky probes retry with backoff, stragglers time out and recover,
  persistently failing buckets quarantine to the analytic estimate with
  provenance that survives the cache AND the artifact round trip;
* corrupt stores (table cache, artifacts) are quarantined to
  ``.corrupt`` files instead of wedging every subsequent load;
* ``AsyncCheckpointer`` as a context manager lands its pending save on
  clean exit and on exception;
* the real SIGKILL-grade kill-and-resume smoke (a child process
  hard-``os._exit``s mid-build) — the same leg ``scripts/verify.sh``
  runs.
"""
import dataclasses
import json
import os

import jax
import numpy as np
import pytest

from repro import runtime
from repro.checkpoint import ckpt
from repro.core import (AnalyticTPUOracle, ProbeConfig, WallClockOracle,
                        build_tables, compress, table_cache)
from repro.core.probe_engine import (PROBE_MEASURED, PROBE_QUARANTINED,
                                     PROBE_RETIMED)
from repro.models import cnn, cnn_host, zoo
from repro.testing import faults
from repro.testing.subproc import run_module


@pytest.fixture(scope="module")
def host():
    net = zoo.tiny_resnet(num_classes=4, in_hw=8, width=4, blocks=(2,))
    params = cnn.init_params(net, jax.random.PRNGKey(0))
    return cnn_host.CNNHost(net, params, batch=4), params


@pytest.fixture(scope="module")
def reference(host):
    """The uninterrupted analytic build every resume must reproduce."""
    h, params = host
    return build_tables(h, params=params)


def _fast_probe(**kw):
    return ProbeConfig(backoff_s=0.0, **kw)


def _tiny_oracle():
    return WallClockOracle(warmup=1, iters=2, groups=1)


# ---------------------------------------------------------------------------
# Fault-plan mechanics
# ---------------------------------------------------------------------------

def test_parse_env_spec():
    plan = faults.parse_env_spec(
        "raise@probe.time:2x3; delay@probe.prepare:1~0.5;exit@tables.bucket")
    a, b, c = plan.rules
    assert (a.point, a.action, a.nth, a.times) == ("probe.time", "raise", 2, 3)
    assert (b.action, b.seconds) == ("delay", 0.5)
    assert (c.point, c.nth, c.times) == ("tables.bucket", 1, 1)
    with pytest.raises(ValueError):
        faults.parse_env_spec("frobnicate@x")
    with pytest.raises(ValueError):
        faults.parse_env_spec("raise@")


def test_parse_env_spec_serve_nan_kv_form():
    """Request-targeted rules use key=value counts; serve_nan_spec
    surfaces them (and only them) to the continuous serve engine."""
    plan = faults.parse_env_spec(
        "nan@serve.nan:rid=1,t=2;delay@serve.chunk:3~0.1")
    a, b = plan.rules
    assert (a.point, a.action, a.rid, a.at) == ("serve.nan", "nan", 1, 2)
    assert (b.point, b.action, b.nth, b.seconds) == ("serve.chunk", "delay",
                                                     3, 0.1)
    with faults.inject(*plan.rules):
        assert faults.serve_nan_spec() == {1: 2}
    assert faults.serve_nan_spec() == {}       # no active plan
    with faults.inject(faults.Fault("serve.nan", "nan", rid=0, at=4),
                       faults.Fault("serve.nan", "nan", rid=3, at=0)):
        assert faults.serve_nan_spec() == {0: 4, 3: 0}


def test_env_reload_picks_up_mutation(monkeypatch):
    """active() caches the env parse; env_reload() re-reads it — the
    contract the serve fault smoke relies on when it flips REPRO_FAULTS
    between its clean and faulted passes."""
    monkeypatch.delenv(faults.ENV_VAR, raising=False)
    faults.env_reload()
    assert faults.active() is None
    monkeypatch.setenv(faults.ENV_VAR, "nan@serve.nan:rid=2,t=1")
    assert faults.active() is None             # stale cache by design
    plan = faults.env_reload()
    assert plan is not None and faults.serve_nan_spec() == {2: 1}
    monkeypatch.delenv(faults.ENV_VAR)
    assert faults.env_reload() is None


def test_tick_clock_is_deterministic():
    clk = faults.TickClock(dt=0.5, t0=2.0)
    assert [clk() for _ in range(3)] == [2.0, 2.5, 3.0]


def test_counted_rules_fire_on_exact_hits():
    with faults.inject(faults.Fault("pt", "raise", nth=2, times=2)) as plan:
        faults.hit("pt")                       # hit 1: unarmed
        with pytest.raises(faults.FaultError):
            faults.hit("pt")                   # hit 2: fires
        with pytest.raises(faults.FaultError):
            faults.hit("pt")                   # hit 3: fires
        faults.hit("pt")                       # hit 4: past the window
        assert [n for (_, n, _) in plan.fired] == [2, 3]
    faults.hit("pt")                           # no active plan: no-op


def test_kill_is_not_swallowed_by_except_exception():
    """FaultKill must behave like SIGKILL: no ``except Exception`` retry
    loop may absorb it."""
    with faults.inject(faults.Fault("pt", "kill")):
        with pytest.raises(faults.FaultKill):
            try:
                faults.hit("pt")
            except Exception:                  # noqa: BLE001
                pytest.fail("FaultKill was caught as an Exception")


# ---------------------------------------------------------------------------
# Journal primitives — torn appends self-heal
# ---------------------------------------------------------------------------

def test_journal_roundtrip_and_torn_tail(tmp_path):
    path = str(tmp_path / "j.journal")
    ckpt.append_journal_line(path, json.dumps({"k": "a", "v": 1.5}))
    ckpt.append_journal_line(path, json.dumps({"k": "b", "v": 2.5}))
    with open(path, "ab") as f:                # crash mid-append: torn tail
        f.write(b'{"k": "c", "v"')
    lines = ckpt.read_journal_lines(path)
    assert [json.loads(l)["k"] for l in lines] == ["a", "b"]
    raw = open(path, "rb").read()              # reader healed the file
    assert raw.endswith(b"\n") and raw.count(b"\n") == 2
    ckpt.append_journal_line(path, json.dumps({"k": "c", "v": 3.5}))
    assert len(ckpt.read_journal_lines(path)) == 3


def test_journal_torn_write_injection(tmp_path):
    """The 'torn' action writes a prefix of the record then dies at the
    fsync point — the reader must drop the fragment."""
    path = str(tmp_path / "j.journal")
    ckpt.append_journal_line(path, json.dumps({"k": "a", "v": 1.0}))
    with faults.inject(faults.Fault("journal.append", "torn", nth=1,
                                    keep_bytes=5)):
        with pytest.raises(faults.FaultKill):
            ckpt.append_journal_line(path, json.dumps({"k": "b", "v": 2.0}))
    lines = ckpt.read_journal_lines(path)
    assert [json.loads(l)["k"] for l in lines] == ["a"]
    ckpt.append_journal_line(path, json.dumps({"k": "b", "v": 2.0}))
    assert len(ckpt.read_journal_lines(path)) == 2


# ---------------------------------------------------------------------------
# Resumable table builds — bit-identical after any injected crash
# ---------------------------------------------------------------------------

def _crash_then_resume(host, reference, cache_dir, rule, **build_kw):
    h, params = host
    with faults.inject(rule):
        with pytest.raises(faults.FaultKill):
            build_tables(h, params=params, cache_dir=cache_dir, **build_kw)
    resumed = build_tables(h, params=params, cache_dir=cache_dir, **build_kw)
    assert resumed.entries == reference.entries
    assert resumed.num_pruned == reference.num_pruned
    return resumed


def test_kill_mid_bucket_resumes_bit_identical(host, reference, tmp_path):
    resumed = _crash_then_resume(
        host, reference, str(tmp_path),
        faults.Fault("tables.bucket", "kill", nth=3))
    # buckets journaled before the kill are replayed, not re-probed
    assert resumed.stats.num_journal_hits >= 2
    assert not list(tmp_path.glob("*.journal"))    # discarded after publish


def test_kill_mid_journal_write_resumes_bit_identical(host, reference,
                                                      tmp_path):
    """A crash that tears the journal record itself: the torn bucket is
    lost (re-probed on resume), earlier buckets replay."""
    resumed = _crash_then_resume(
        host, reference, str(tmp_path),
        faults.Fault("journal.append", "torn", nth=4))
    assert resumed.stats.num_journal_hits == 3     # buckets 1-3 survived


def test_kill_mid_publish_resumes_bit_identical(host, reference, tmp_path):
    """Crash after every probe journaled but before the tables published:
    the resume replays the ENTIRE build from the journal."""
    resumed = _crash_then_resume(
        host, reference, str(tmp_path),
        faults.Fault("table_cache.publish", "kill"))
    assert resumed.stats.num_journal_hits == resumed.stats.num_latency_buckets


def test_no_resume_discards_journal(host, reference, tmp_path):
    h, params = host
    with faults.inject(faults.Fault("tables.bucket", "kill", nth=3)):
        with pytest.raises(faults.FaultKill):
            build_tables(h, params=params, cache_dir=str(tmp_path))
    fresh = build_tables(h, params=params, cache_dir=str(tmp_path),
                         resume=False)
    assert fresh.stats.num_journal_hits == 0
    assert fresh.entries == reference.entries


def test_cache_hit_cleans_stale_journal(host, tmp_path):
    """A journal that survived into the publish→cleanup crash window is
    subsumed by the published tables and removed on the next build."""
    h, params = host
    built = build_tables(h, params=params, cache_dir=str(tmp_path))
    key = table_cache.cache_key(h, AnalyticTPUOracle(), "layermerge",
                                "magnitude")
    open(table_cache.journal_path(str(tmp_path), key), "w").write(
        '{"k": "stale", "v": 1.0, "p": "measured"}\n')
    warm = build_tables(h, params=params, cache_dir=str(tmp_path))
    assert warm.stats.cache_hit and warm.entries == built.entries
    assert not os.path.exists(table_cache.journal_path(str(tmp_path), key))


def test_sequential_engine_resumes_too(host, tmp_path):
    h, params = host
    ref = build_tables(h, params=params, engine="sequential")
    with faults.inject(faults.Fault("tables.bucket", "kill", nth=5)):
        with pytest.raises(faults.FaultKill):
            build_tables(h, params=params, engine="sequential",
                         cache_dir=str(tmp_path))
    resumed = build_tables(h, params=params, engine="sequential",
                           cache_dir=str(tmp_path))
    assert resumed.entries == ref.entries
    assert resumed.stats.num_journal_hits >= 4


def test_importance_probes_resume(tmp_path):
    """Measured-importance builds journal per-probe and resume without
    re-tuning completed span groups."""
    from repro.core import ImportanceSpec, accuracy_perf, xent_loss

    net = zoo.tiny_resnet(num_classes=4, in_hw=8, width=4, blocks=(1,))
    params = cnn.init_params(net, jax.random.PRNGKey(0))
    h = cnn_host.CNNHost(net, params, batch=4)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 8, 8, 3))
    y = jax.random.randint(jax.random.PRNGKey(2), (8,), 0, 4)
    spec = ImportanceSpec(loss_fn=xent_loss, perf_fn=accuracy_perf,
                          train_batches=[(x, y)], eval_batches=[(x, y)],
                          steps=2, lr=1e-3, cache_token="faults-v1")
    base = accuracy_perf(lambda p, xx: cnn.apply_replaced(net, p, xx),
                         params, spec.eval_batches)
    ref = build_tables(h, params=params, importance=spec, base_perf=base)
    with faults.inject(faults.Fault("tables.importance", "kill", nth=2)):
        with pytest.raises(faults.FaultKill):
            build_tables(h, params=params, importance=spec, base_perf=base,
                         cache_dir=str(tmp_path))
    resumed = build_tables(h, params=params, importance=spec,
                           base_perf=base, cache_dir=str(tmp_path))
    assert resumed.entries == ref.entries
    assert resumed.stats.num_journal_hits > 0


# ---------------------------------------------------------------------------
# Probe hardening — retry, timeout, straggler, quarantine, provenance
# ---------------------------------------------------------------------------

def test_flaky_probe_retries_then_succeeds(host):
    h, params = host
    with faults.inject(faults.Fault("probe.time", "raise", nth=1, times=2)):
        tb = build_tables(h, latency_oracle=_tiny_oracle(), params=params,
                          probe_config=_fast_probe())
    assert tb.stats.num_probe_retries >= 2
    assert tb.stats.num_quarantined == 0
    assert tb.provenance == {}                 # clean after retries


def test_persistent_failure_quarantines_to_analytic(host):
    h, params = host
    with faults.inject(faults.Fault("probe.time", "raise", nth=1, times=3)):
        tb = build_tables(h, latency_oracle=_tiny_oracle(), params=params,
                          probe_config=_fast_probe(retries=2), prune=False)
    assert tb.stats.num_quarantined == 1       # first bucket gave up
    assert set(tb.provenance.values()) == {PROBE_QUARANTINED}
    # quarantined entries carry the deterministic analytic estimate — the
    # same value AnalyticTPUOracle derives from the segment's static cost
    for (i, j, k) in tb.provenance:
        assert tb.entries[(i, j)][k][1] > 0.0


def test_probe_timeout_quarantines_everything(host):
    h, params = host
    cfg = _fast_probe(timeout_s=1e-9, retries=0)
    tb = build_tables(h, latency_oracle=_tiny_oracle(), params=params,
                      probe_config=cfg)
    assert tb.stats.num_quarantined == tb.stats.num_latency_buckets
    assert all(lat > 0.0 for row in tb.entries.values()
               for _, lat, _ in row.values())


def test_straggler_delay_recovers_on_retry(host):
    h, params = host
    cfg = _fast_probe(timeout_s=0.25, retries=2)
    with faults.inject(faults.Fault("probe.time", "delay", nth=1,
                                    seconds=0.4)):
        tb = build_tables(h, latency_oracle=_tiny_oracle(), params=params,
                          probe_config=cfg)
    assert tb.stats.num_probe_retries >= 1     # the straggler retried fast
    assert tb.stats.num_quarantined == 0
    assert tb.provenance == {}


def test_quarantine_disabled_propagates(host):
    h, params = host
    cfg = _fast_probe(retries=0, quarantine=False)
    with faults.inject(faults.Fault("probe.time", "raise", times=99)):
        with pytest.raises(faults.FaultError):
            build_tables(h, latency_oracle=_tiny_oracle(), params=params,
                         probe_config=cfg)


@dataclasses.dataclass
class _SpikyOracle(WallClockOracle):
    """First measurement reports an outlier spread, later ones are calm —
    deterministic trigger for the variance-based re-timing."""

    def time_callable_stats(self, fn, *, warmup=None):
        med, _ = super().time_callable_stats(fn, warmup=warmup)
        n = self.__dict__["_n"] = self.__dict__.get("_n", 0) + 1
        return med, (10.0 if n == 1 else 0.0)


def test_outlier_spread_triggers_retiming_with_provenance(host, tmp_path):
    h, params = host
    oracle = _SpikyOracle(warmup=1, iters=2, groups=1)
    tb = build_tables(h, latency_oracle=oracle, params=params,
                      probe_config=_fast_probe(outlier_rel_spread=1.0),
                      cache_dir=str(tmp_path), prune=False)
    assert tb.stats.num_retimed == 1
    assert PROBE_RETIMED in set(tb.provenance.values())
    # provenance flags survive the content-addressed cache round trip
    warm = build_tables(h, latency_oracle=_SpikyOracle(warmup=1, iters=2,
                                                       groups=1),
                        params=params,
                        probe_config=_fast_probe(outlier_rel_spread=1.0),
                        cache_dir=str(tmp_path), prune=False)
    assert warm.stats.cache_hit
    assert warm.provenance == tb.provenance


def test_quarantine_provenance_survives_artifact_roundtrip(host, tmp_path):
    """ISSUE acceptance: quarantined-bucket flags must ride the plan all
    the way into the published artifact's meta."""
    h, params = host
    cfg = _fast_probe(timeout_s=1e-9, retries=0)
    res = compress(h, budget_ratio=1.0, P=100,
                   latency_oracle=_tiny_oracle(), params=params,
                   probe_config=cfg)
    assert res is not None and len(res.tables.provenance) > 0
    path = str(tmp_path / "flagged.npz")
    res.save(path)
    art = runtime.load(path)
    prov = art.meta["probe_provenance"]
    assert len(prov) == len(res.tables.provenance)
    assert all(p["flag"] == PROBE_QUARANTINED for p in prov)
    assert PROBE_MEASURED not in {p["flag"] for p in prov}


# ---------------------------------------------------------------------------
# Self-healing stores — quarantine-on-load
# ---------------------------------------------------------------------------

def test_corrupt_table_cache_quarantined_and_rebuilt(host, tmp_path):
    h, params = host
    build_tables(h, params=params, cache_dir=str(tmp_path))
    key = table_cache.cache_key(h, AnalyticTPUOracle(), "layermerge",
                                "magnitude")
    path = tmp_path / f"tables_{key}.json"
    path.write_text(path.read_text()[:40])     # truncated cache file
    again = build_tables(h, params=params, cache_dir=str(tmp_path))
    assert not again.stats.cache_hit           # miss, not a crash
    assert (tmp_path / f"tables_{key}.json.corrupt").exists()
    healed = build_tables(h, params=params, cache_dir=str(tmp_path))
    assert healed.stats.cache_hit              # rebuild re-published


def test_corrupt_artifact_quarantined_with_hint(host, tmp_path):
    h, params = host
    res = compress(h, budget_ratio=1.0, P=100, params=params)
    path = str(tmp_path / "model.npz")
    res.save(path)
    raw = open(path, "rb").read()
    open(path, "wb").write(raw[: len(raw) // 3])
    with pytest.raises(runtime.ArtifactError, match="quarantined"):
        runtime.load(path)
    assert os.path.exists(path + ".corrupt")
    assert not os.path.exists(path)            # read path is clear again
    res.save(path)                             # recovery: re-publish
    assert runtime.load(path).plan == res.plan


# ---------------------------------------------------------------------------
# AsyncCheckpointer context manager
# ---------------------------------------------------------------------------

def test_async_checkpointer_context_flushes_on_exit(tmp_path):
    d = str(tmp_path / "ckpt")
    with ckpt.AsyncCheckpointer(d) as c:
        c.save(1, {"w": np.ones((3,), np.float32)})
    assert ckpt.latest_step(d) == 1            # joined, no .wait() needed


def test_async_checkpointer_context_flushes_on_exception(tmp_path):
    d = str(tmp_path / "ckpt")
    with pytest.raises(RuntimeError, match="body"):
        with ckpt.AsyncCheckpointer(d) as c:
            c.save(2, {"w": np.zeros((3,), np.float32)})
            raise RuntimeError("body failed")
    assert ckpt.latest_step(d) == 2            # save landed anyway


# ---------------------------------------------------------------------------
# The real thing: hard os._exit mid-build in a child process
# ---------------------------------------------------------------------------

def test_kill_resume_smoke_subprocess():
    """Run the verify.sh smoke in-process: child dies with exit 17 at the
    4th journaled bucket, parent resumes bit-identically."""
    out = faults.kill_resume_smoke(kill_at_bucket=4)
    assert out["bit_identical"]
    assert out["journal_hits_on_resume"] >= 3


def test_serve_fault_smoke_inprocess():
    """The verify.sh serve leg: NaN + delayed arrival + straggler chunk
    under an env spec, survivors bit-identical to the clean run."""
    out = faults.serve_fault_smoke()
    assert out["survivors_bit_identical"]
    assert out["aborted"] == {1: 2}
    assert len(out["delay_rules_fired"]) >= 2


def test_faults_cli_smoke_flag():
    r = run_module("repro.testing.faults", "--smoke", timeout=600)
    assert "FAULT_SMOKE_OK" in r.stdout, r.stdout + r.stderr
