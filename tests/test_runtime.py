"""repro.runtime certification: one shared executor + portable artifacts.

* executor-vs-legacy equivalence (allclose) on every zoo CNN host and on
  transformer hosts across sublayer families, under both ``replaced``
  (unmerged) and ``merged`` modes;
* artifact save → load → re-execute round trips with fingerprint
  stability, including a fresh-process reload (bit-identical plan,
  equivalent outputs);
* corrupt / torn / stale artifacts are rejected (the table-cache torn-
  file contract, but *loud*: deployment must never run a bit-rotted
  model silently);
* the ``python -m repro.compress`` CLI produces a loadable artifact.
"""
import dataclasses
import json
import os
import subprocess
import sys

from repro.testing.subproc import subprocess_env

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import runtime
from repro.configs import get_config
from repro.core import compress
from repro.core.plan import identity_plan
from repro.models import cnn, cnn_host, zoo
from repro.models import transformer as T
from repro.models.transformer_host import CostEnv, TransformerHost

_SUBPROC_ENV = subprocess_env()

CNN_ZOO = {
    "tiny_resnet": lambda: zoo.tiny_resnet(num_classes=4, in_hw=8, width=4,
                                           blocks=(2,)),
    "tiny_mobilenet": lambda: zoo.tiny_mobilenet(num_classes=4, in_hw=8,
                                                 width=8),
    "tiny_unet": lambda: zoo.tiny_unet(in_hw=8, base=4, norm="gn",
                                       attn=True),
}

TRANSFORMER_ARCHS = ("smollm-135m", "granite-moe-1b-a400m",
                     "recurrentgemma-2b")


def _cnn_setup(name):
    net = CNN_ZOO[name]()
    params = cnn.init_params(net, jax.random.PRNGKey(0))
    host = cnn_host.CNNHost(net, params, batch=2)
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (2, net.in_hw, net.in_hw, net.in_ch))
    return net, params, host, x


def _tf_setup(arch, num_layers=None):
    cfg = get_config(arch).reduced()
    if num_layers is not None:
        cfg = dataclasses.replace(cfg, num_layers=num_layers)
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    host = TransformerHost(cfg, params, env=CostEnv(batch=2, seq=16))
    B, S = 2, 16
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                          cfg.vocab_size),
             "positions": jnp.broadcast_to(jnp.arange(S)[None], (B, S))}
    return cfg, params, host, batch


def _allclose(a, b, rtol=1e-4):
    scale = float(jnp.abs(a).max()) + 1e-9
    assert float(jnp.abs(a - b).max()) / scale < rtol, \
        float(jnp.abs(a - b).max())


# ---------------------------------------------------------------------------
# Executor vs legacy forward paths
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(CNN_ZOO))
def test_cnn_executor_matches_legacy(name):
    """Merged executor ≈ legacy replaced forward (the paper's exactness)
    on compressed plans, and ≡ it on the identity plan."""
    net, params, host, x = _cnn_setup(name)
    tested = 0
    for ratio in (0.6, 0.8):
        res = compress(host, budget_ratio=ratio, P=100)
        if res is None:
            continue
        y_legacy = cnn.apply_replaced(net, params, x, res.plan)
        y_exec = runtime.execute(host.lower_plan(res.plan), x)
        _allclose(y_legacy, y_exec)
        ma, _ = host.merged_apply(res.plan)
        np.testing.assert_array_equal(np.asarray(ma(params, x)),
                                      np.asarray(y_exec))
        tested += 1
    assert tested > 0
    ident = identity_plan(net.L, net.layer_descs())
    y0 = cnn.apply_replaced(net, params, x)
    y0_exec = runtime.execute(host.lower_plan(ident), x)
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y0_exec))


@pytest.mark.parametrize("arch", TRANSFORMER_ARCHS)
def test_transformer_executor_matches_legacy(arch):
    """Executor (replaced + merged graphs) ≈ the legacy tuple-unit
    ``T.forward_compressed`` path, across attn/ffn/moe/rglru sublayers."""
    cfg, params, host, batch = _tf_setup(arch)
    tested = 0
    for ratio in (0.6, 0.8):
        res = compress(host, budget_ratio=ratio, P=100)
        if res is None:
            continue
        for merged in (False, True):
            graph = host.lower_plan(res.plan, merged=merged)
            legacy_units = [
                ("merged", (u.params["u"], u.params["v"]))
                if u.kind == "lowrank" else
                ("orig", {"norm": u.params["norm"], "p": u.params["p"],
                          "kind": u.sub_kind})
                for u in graph.units]
            y_legacy = T.forward_compressed(cfg, params, legacy_units, batch)
            y_exec = runtime.execute(graph, batch)
            _allclose(y_legacy, y_exec)
        ra, _ = host.replaced_apply(res.plan)
        ma, _ = host.merged_apply(res.plan)
        _allclose(ra(params, batch), ma(params, batch))
        tested += 1
    assert tested > 0


def test_jit_apply_params_pytree():
    """jit_apply exposes the graph's arrays as a pytree argument; scaling
    the head through the pytree must change the output (no stale
    closure-captured constants)."""
    net, params, host, x = _cnn_setup("tiny_resnet")
    res = compress(host, budget_ratio=0.7, P=100)
    graph = host.lower_plan(res.plan)
    fn, gp = runtime.jit_apply(graph)
    y = fn(gp, x)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(runtime.execute(graph, x)),
                               rtol=1e-6, atol=1e-6)
    gp2 = jax.tree.map(lambda a: a, gp)
    gp2["globals"]["head"]["w"] = gp["globals"]["head"]["w"] * 2.0
    assert float(jnp.abs(fn(gp2, x) - y).max()) > 0


# ---------------------------------------------------------------------------
# KV-cache decode
# ---------------------------------------------------------------------------

def test_decode_matches_prefill():
    """Token-by-token decode through the compressed graph reproduces the
    parallel prefill logits at the last position (KV-cache correctness)."""
    cfg, params, host, batch = _tf_setup("smollm-135m", num_layers=4)
    res = compress(host, budget_ratio=0.6, P=200)
    graph = host.lower_plan(res.plan)
    y = runtime.execute(graph, batch)
    B, S = batch["tokens"].shape
    cache = runtime.init_cache(graph, B, S)
    step, gp = runtime.make_serve_step(graph)
    step = jax.jit(step)
    logits = None
    for t in range(S):
        logits, cache = step(gp, cache,
                             {"tokens": batch["tokens"][:, t:t + 1]})
    _allclose(y[:, -1], logits[:, 0], rtol=2e-4)


# ---------------------------------------------------------------------------
# Artifact round trips
# ---------------------------------------------------------------------------

def _save_cnn_artifact(tmp_path, name="tiny_resnet", ratio=0.7):
    net, params, host, x = _cnn_setup(name)
    res = compress(host, budget_ratio=ratio, P=100)
    path = os.path.join(tmp_path, f"{name}.npz")
    fp = res.save(path)
    return res, host, x, path, fp


def test_artifact_roundtrip_cnn(tmp_path):
    res, host, x, path, fp = _save_cnn_artifact(str(tmp_path))
    art = runtime.load(path)
    assert art.fingerprint == fp
    assert art.plan == res.plan                       # bit-identical plan
    assert art.meta["oracle"] and "AnalyticTPUOracle" in art.meta["oracle"]
    y_live = runtime.execute(host.lower_plan(res.plan), x)
    np.testing.assert_array_equal(np.asarray(y_live),
                                  np.asarray(art.apply(x)))


def test_artifact_roundtrip_transformer(tmp_path):
    cfg, params, host, batch = _tf_setup("smollm-135m", num_layers=4)
    res = compress(host, budget_ratio=0.6, P=200)
    path = os.path.join(str(tmp_path), "lm.npz")
    res.save(path)
    art = runtime.load(path)
    assert art.plan == res.plan
    assert art.graph.meta["config"] == cfg            # ArchConfig round-trip
    y_live = runtime.execute(host.lower_plan(res.plan), batch)
    np.testing.assert_array_equal(np.asarray(y_live),
                                  np.asarray(art.apply(batch)))


def test_artifact_fingerprint_stable(tmp_path):
    """Same graph + plan + meta ⇒ same fingerprint, across saves and
    across a load→save round trip (content addressing, not timestamps)."""
    res, host, x, path, fp1 = _save_cnn_artifact(str(tmp_path))
    fp2 = res.save(os.path.join(str(tmp_path), "again.npz"))
    assert fp1 == fp2
    art = runtime.load(path)
    fp3 = runtime.save(os.path.join(str(tmp_path), "resaved.npz"),
                       art.graph, plan=art.plan, meta=art.meta)
    assert fp3 == fp1
    # different weights ⇒ different fingerprint
    net = CNN_ZOO["tiny_resnet"]()
    params2 = cnn.init_params(net, jax.random.PRNGKey(7))
    host2 = cnn_host.CNNHost(net, params2, batch=2)
    fp4 = runtime.fingerprint(host2.lower_plan(res.plan), res.plan,
                              art.meta)
    assert fp4 != fp1


def test_artifact_fresh_process_reload(tmp_path):
    """An artifact written here reloads in a FRESH process to a
    bit-identical plan and equivalent outputs."""
    res, host, x, path, fp = _save_cnn_artifact(str(tmp_path))
    y_live = np.asarray(runtime.execute(host.lower_plan(res.plan), x))
    xpath = os.path.join(str(tmp_path), "x.npy")
    np.save(xpath, np.asarray(x))
    code = (
        "import sys, json, numpy as np\n"
        "from repro import runtime\n"
        "art = runtime.load(sys.argv[1])\n"
        "y = np.asarray(art.apply(np.load(sys.argv[2])))\n"
        "np.save(sys.argv[3], y)\n"
        "print('PLAN=' + art.plan.to_json().replace(chr(10), ''))\n"
        "print('FP=' + art.fingerprint)\n"
    )
    ypath = os.path.join(str(tmp_path), "y.npy")
    r = subprocess.run([sys.executable, "-c", code, path, xpath, ypath],
                       capture_output=True, text=True, env=_SUBPROC_ENV,
                       cwd="/root/repo", timeout=300)
    assert r.returncode == 0, r.stdout + r.stderr
    assert f"FP={fp}" in r.stdout
    plan_line = [l for l in r.stdout.splitlines()
                 if l.startswith("PLAN=")][0]
    from repro.core.plan import CompressionPlan
    assert CompressionPlan.from_json(plan_line[5:]) == res.plan
    np.testing.assert_allclose(np.load(ypath), y_live, rtol=1e-5,
                               atol=1e-6)


def test_artifact_finetune_consumer(tmp_path):
    """A reloaded artifact is trainable: ``make_train_step`` over the
    graph's params pytree takes finite, loss-reducing AdamW steps —
    compression runs once and fine-tuning resumes from the same object
    serving uses."""
    from repro.optim.adamw import AdamWConfig, init_opt_state
    from repro.train.step import make_compressed_forward, make_train_step

    cfg, params, host, batch = _tf_setup("smollm-135m", num_layers=4)
    res = compress(host, budget_ratio=0.6, P=200)
    path = os.path.join(str(tmp_path), "lm.npz")
    res.save(path)
    art = runtime.load(path)
    gp = runtime.graph_params(art.graph)
    step = jax.jit(make_train_step(
        cfg, AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10),
        forward_fn=make_compressed_forward(art.graph)))
    tbatch = dict(batch)
    tbatch["targets"] = jnp.roll(batch["tokens"], -1, axis=1)
    opt = init_opt_state(gp)
    losses = []
    for _ in range(5):
        gp, opt, metrics = step(gp, opt, tbatch)
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(l) for l in losses)
    assert losses[-1] < losses[0]


# ---------------------------------------------------------------------------
# Corrupt / torn / stale artifacts are rejected
# ---------------------------------------------------------------------------

def test_artifact_missing_is_error(tmp_path):
    with pytest.raises(runtime.ArtifactError):
        runtime.load(os.path.join(str(tmp_path), "nope.npz"))


def test_artifact_torn_write_rejected(tmp_path):
    """A truncated file (crash mid-write without the atomic rename) must
    raise, mirroring test_probe_engine's torn-cache case."""
    _, _, _, path, _ = _save_cnn_artifact(str(tmp_path))
    raw = open(path, "rb").read()
    open(path, "wb").write(raw[: len(raw) // 3])
    with pytest.raises(runtime.ArtifactError):
        runtime.load(path)
    assert not os.path.exists(path + ".tmp")    # atomic publish leaves none


def test_artifact_bitrot_rejected(tmp_path):
    """A structurally-valid npz whose weights were tampered with must
    fail fingerprint verification."""
    _, _, _, path, _ = _save_cnn_artifact(str(tmp_path))
    with np.load(path, allow_pickle=False) as z:
        data = {k: z[k] for k in z.files}
    wkey = sorted(k for k in data if k.endswith("/w"))[0]
    data[wkey] = data[wkey] + 1.0
    with open(path, "wb") as f:
        np.savez(f, **data)
    with pytest.raises(runtime.ArtifactError, match="fingerprint"):
        runtime.load(path)


def test_artifact_stale_format_rejected(tmp_path):
    _, _, _, path, _ = _save_cnn_artifact(str(tmp_path))
    with np.load(path, allow_pickle=False) as z:
        data = {k: z[k] for k in z.files}
    spec = json.loads(data["__spec__"].item())
    spec["format"] = 99
    data["__spec__"] = np.array(json.dumps(spec))
    with open(path, "wb") as f:
        np.savez(f, **data)
    with pytest.raises(runtime.ArtifactError, match="format"):
        runtime.load(path)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_mobilenet_e2e_pallas_certified(tmp_path):
    """MobileNetV2-family end-to-end: compress → every conv unit of the
    lowered graph (pointwise, depthwise, strided, merged-fat) certified on
    the Pallas kernels in interpret mode against the ref oracles → artifact
    → fresh-process reload exactness.

    Uses ``tiny_mobilenet`` — the same inverted-residual generator as the
    ``mobilenetv2`` zoo config (expand 1×1 / depthwise 3×3 / project 1×1,
    strided blocks included) at CI scale."""
    net, params, host, x = _cnn_setup("tiny_mobilenet")
    res = compress(host, budget_ratio=0.7, P=100)
    assert res is not None
    graph = host.lower_plan(res.plan)
    conv_units = [u for u in graph.units if u.kind == "conv"]
    dw_units = [u for u in conv_units if u.depthwise]
    assert dw_units, "plan kept no depthwise unit — not exercising the path"
    # every conv unit runs its deployment kernel (interpret on CPU) and
    # matches the jnp oracle at the unit's real weights and geometry
    from repro import kernels
    rng = np.random.default_rng(0)
    with kernels.force_backend("pallas"):
        for u in conv_units:
            w, b = u.params["w"], u.params["b"]
            K = w.shape[0]
            cin = w.shape[3] if u.depthwise else w.shape[2]
            hw = K + 3 * u.stride
            xin = jnp.asarray(rng.standard_normal((1, hw, hw, cin)),
                              jnp.float32)
            if u.depthwise:
                y = kernels.depthwise_conv_op(xin, w, b, stride=u.stride,
                                              interpret=True)
                yr = kernels.depthwise_conv_ref(xin, w, b, stride=u.stride)
            else:
                y = kernels.merged_conv_op(xin, w, b, stride=u.stride,
                                           interpret=True)
                yr = kernels.merged_conv_ref(xin, w, b, stride=u.stride)
            np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                       rtol=2e-5, atol=2e-5)
    # artifact round trip: fresh process, bit-identical plan, equal outputs
    path = os.path.join(str(tmp_path), "mnv2.npz")
    fp = res.save(path)
    y_live = np.asarray(runtime.execute(graph, x))
    xpath = os.path.join(str(tmp_path), "x.npy")
    np.save(xpath, np.asarray(x))
    code = (
        "import sys, numpy as np\n"
        "from repro import runtime\n"
        "art = runtime.load(sys.argv[1])\n"
        "np.save(sys.argv[3], np.asarray(art.apply(np.load(sys.argv[2]))))\n"
        "print('FP=' + art.fingerprint)\n"
        "print('DW=%d' % sum(1 for u in art.graph.units\n"
        "                    if u.kind == 'conv' and u.depthwise))\n"
    )
    ypath = os.path.join(str(tmp_path), "y.npy")
    r = subprocess.run([sys.executable, "-c", code, path, xpath, ypath],
                       capture_output=True, text=True, env=_SUBPROC_ENV,
                       cwd="/root/repo", timeout=300)
    assert r.returncode == 0, r.stdout + r.stderr
    assert f"FP={fp}" in r.stdout
    assert f"DW={len(dw_units)}" in r.stdout
    np.testing.assert_allclose(np.load(ypath), y_live, rtol=1e-5, atol=1e-6)


def test_compress_cli_writes_loadable_artifact(tmp_path):
    out = os.path.join(str(tmp_path), "cli.npz")
    r = subprocess.run(
        [sys.executable, "-m", "repro.compress", "--arch", "tiny_mobilenet",
         "--budget-ratio", "0.7", "--P", "100", "--out", out],
        capture_output=True, text=True, env=_SUBPROC_ENV, cwd="/root/repo",
        timeout=300)
    assert r.returncode == 0, r.stdout + r.stderr
    art = runtime.load(out)
    assert art.graph.family == "cnn"
    assert art.meta["source"]["arch"] == "tiny_mobilenet"
    assert art.plan is not None and len(art.plan.segments) >= 1
    x = jnp.zeros((1, 16, 16, 3))
    assert art.apply(x).shape == (1, 4)
