"""Pallas TPU kernel: RG-LRU linear recurrence  h_t = a_t ⊙ h_{t-1} + b_t.

Grid (batch, channel-tiles, time-tiles), time innermost; the carry h lives
in VMEM scratch and persists across time tiles.  Within a tile the scan is
a sequential fori_loop over rows — the VPU processes a full (bc,) channel
vector per step, so the kernel is bandwidth-bound exactly like the
recurrence itself; tiling time bounds the VMEM residency of a/b to
(bt × bc) each.

Decode (one step) is a trivial fused multiply-add and stays in XLA.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, o_ref, h_ref, *, bt: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _():
        h_ref[...] = jnp.zeros_like(h_ref)

    def step(t, h):
        h = a_ref[t, :] * h + b_ref[t, :]
        o_ref[t, :] = h
        return h
    h = jax.lax.fori_loop(0, bt, step, h_ref[...])
    h_ref[...] = h


def rglru_scan(a, b, *, bc: int = 512, bt: int = 256,
               interpret: bool = False):
    """a, b: (B, S, C) fp32 → h: (B, S, C).  S % bt == 0, C % bc == 0."""
    bsz, s, c = a.shape
    bc = min(bc, c)
    bt = min(bt, s)
    assert s % bt == 0 and c % bc == 0, "pad at the ops layer"
    grid = (bsz, c // bc, s // bt)
    return pl.pallas_call(
        functools.partial(_kernel, bt=bt),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, bt, bc), lambda n, ci, ti: (n, ti, ci)),
            pl.BlockSpec((None, bt, bc), lambda n, ci, ti: (n, ti, ci)),
        ],
        out_specs=pl.BlockSpec((None, bt, bc), lambda n, ci, ti: (n, ti, ci)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, c), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bc,), jnp.float32)],
        interpret=interpret,
    )(a.astype(jnp.float32), b.astype(jnp.float32))
