"""Deterministic synthetic data pipeline — sharded, prefetched, resumable.

No datasets ship in this container, so the pipeline synthesizes token
streams with learnable structure (an order-2 Markov language over the
vocab): losses drop meaningfully during the example training runs, which is
what the end-to-end driver needs to demonstrate.

Design points that matter at scale and are exercised in tests:
* **Determinism / resumability** — batch ``i`` is a pure function of
  (seed, i): restarting from a checkpoint at step ``s`` replays the exact
  stream by construction, with no iterator state to save.
* **Sharded global batches** — ``GlobalBatcher`` materializes each batch as
  a jax.Array sharded over the mesh's data axes
  (``jax.make_array_from_callback``: every host builds only its shard).
* **Prefetch** — a depth-``k`` background thread keeps the accelerator fed.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import jax
import numpy as np


class MarkovLM:
    """Order-2 synthetic language with a low-entropy transition table."""

    def __init__(self, vocab_size: int, seed: int = 0, branching: int = 4):
        self.vocab = vocab_size
        rng = np.random.default_rng(seed)
        self.table = rng.integers(0, vocab_size,
                                  size=(vocab_size, branching)).astype(np.int32)

    def sample(self, rng: np.random.Generator, batch: int, seq: int):
        toks = np.empty((batch, seq + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, size=batch)
        branch = rng.integers(0, self.table.shape[1], size=(batch, seq))
        for t in range(seq):
            toks[:, t + 1] = self.table[toks[:, t], branch[:, t]]
        return toks


class SyntheticTokens:
    """batch(i) → {'tokens','targets','positions'} — pure in (seed, i)."""

    def __init__(self, vocab_size: int, batch: int, seq: int, seed: int = 0):
        self.lm = MarkovLM(vocab_size, seed)
        self.batch, self.seq, self.seed = batch, seq, seed

    def batch_at(self, index: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, index))
        toks = self.lm.sample(rng, self.batch, self.seq)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:],
                "positions": np.broadcast_to(np.arange(self.seq, dtype=np.int32),
                                             (self.batch, self.seq)).copy()}


class GlobalBatcher:
    """Materializes host batches as mesh-sharded global jax.Arrays."""

    def __init__(self, source, mesh=None, batch_axes=("data",)):
        self.source = source
        self.mesh = mesh
        self.batch_axes = batch_axes

    def __call__(self, index: int):
        host = self.source.batch_at(index)
        if self.mesh is None:
            return {k: jax.numpy.asarray(v) for k, v in host.items()}
        from jax.sharding import NamedSharding, PartitionSpec as P
        axes = tuple(a for a in self.batch_axes if a in self.mesh.shape)
        out = {}
        for k, v in host.items():
            sharding = NamedSharding(self.mesh, P(axes))
            out[k] = jax.make_array_from_callback(
                v.shape, sharding, lambda idx, v=v: v[idx])
        return out


def prefetch(batch_fn, start: int, depth: int = 2) -> Iterator:
    """Depth-k background prefetch of batch_fn(start), batch_fn(start+1)…"""
    q: queue.Queue = queue.Queue(maxsize=depth)
    stop = threading.Event()

    def producer():
        i = start
        while not stop.is_set():
            try:
                q.put((i, batch_fn(i)), timeout=0.5)
                i += 1
            except queue.Full:
                continue
    th = threading.Thread(target=producer, daemon=True)
    th.start()
    try:
        while True:
            yield q.get()
    finally:
        stop.set()
