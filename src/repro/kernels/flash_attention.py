"""Pallas TPU kernel: causal flash attention (forward).

Online-softmax tiling: grid (batch·heads, q-tiles, kv-tiles), kv innermost;
running max / normalizer / output accumulator live in VMEM scratch and
persist across the kv sweep.  Causal skipping: kv tiles strictly above the
diagonal are skipped (``pl.when``), the diagonal tile is masked.

Used for serve/prefill; training uses ``jax.custom_vjp`` with this forward
and the jnp reference backward (ops.py) — recompute-style, matching the
remat policy of the training stack.

VMEM per step (bq=bk=512, d=128, fp32 acc): q 512×128·2, k/v 512×128·2 ×2,
acc 512×128·4, m/l 512·4 ×2 → < 1 MiB.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bq: int, bk: int, scale: float, causal: bool):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = (not causal) or (ki * bk <= qi * bq + bq - 1)

    @pl.when(run)
    def _():
        q = q_ref[...].astype(jnp.float32)
        k = k_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
            p, v_ref[...].astype(jnp.float32),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _():
        o_ref[...] = (acc_ref[...]
                      / jnp.maximum(l_ref[...], 1e-30)[:, None]
                      ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, bq: int = 512,
                    bk: int = 512, interpret: bool = False):
    """q, k, v: (BH, S, D) → (BH, S, D).  S must tile by bq/bk."""
    bh, s, d = q.shape
    bq = min(bq, s)
    bk = min(bk, s)
    assert s % bq == 0 and s % bk == 0, "pad sequence at the ops layer"
    grid = (bh, s // bq, s // bk)
    kernel = functools.partial(_kernel, bq=bq, bk=bk,
                               scale=1.0 / math.sqrt(d), causal=causal)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((None, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((None, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
