"""§Perf hillclimb helper: compare depth-corrected roofline terms between a
baseline cell and tagged variants.

  PYTHONPATH=src python benchmarks/perf_compare.py \
      --arch command-r-plus-104b --shape decode_32k --tags flash,...
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys

sys.path.insert(0, os.path.dirname(__file__))
import roofline


def load_variant(arch, shape, mesh="single", tag=None,
                 dirpath="results/dryrun"):
    suffix = f"__{tag}" if tag else ""
    main = os.path.join(dirpath, f"{arch}__{shape}__{mesh}{suffix}.json")
    rec = json.load(open(main))
    assert rec.get("status") == "ok", rec.get("error")
    key = (arch, shape)
    probe_suffix = f"-{tag}" if tag else ""
    probes = []
    for path in glob.glob(os.path.join(
            dirpath, f"{arch}__{shape}__{mesh}__probe*{probe_suffix}.json")):
        m = re.search(rf"__probe(\d+){re.escape(probe_suffix)}\.json$", path)
        if not m:
            continue
        p = json.load(open(path))
        if p.get("status") == "ok":
            probes.append(p)
    probes.sort(key=lambda r: r["num_layers"])
    p1 = {key: probes[0]} if probes else {}
    p2 = {key: probes[1]} if len(probes) > 1 else {}
    rec = roofline.depth_correct(rec, (p1, p2))
    return roofline.analyse(rec, mesh)


def fmt(r):
    return (f"compute={r['compute_s']:.4e}s  mem(tpu)={r['analytic_memory_s']:.4e}s "
            f"mem(hlo)={r['memory_s']:.4e}s  coll={r['collective_s']:.4e}s  "
            f"dom={r['dominant_tpu']}  RF={r['roofline_fraction_tpu']:.4f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--tags", default="")
    args = ap.parse_args()
    base = load_variant(args.arch, args.shape, args.mesh)
    print(f"baseline       : {fmt(base)}")
    for tag in filter(None, args.tags.split(",")):
        try:
            v = load_variant(args.arch, args.shape, args.mesh, tag)
        except (FileNotFoundError, AssertionError) as e:
            print(f"{tag:15s}: MISSING/FAILED ({e})")
            continue
        dom = base["dominant_tpu"]
        key = {"compute": "compute_s", "memory": "analytic_memory_s",
               "collective": "collective_s"}[dom]
        delta = (base[key] - v[key]) / max(base[key], 1e-30) * 100
        print(f"{tag:15s}: {fmt(v)}")
        print(f"{'':15s}  Δ dominant({dom}): {delta:+.1f}%  "
              f"RF {base['roofline_fraction_tpu']:.4f} -> "
              f"{v['roofline_fraction_tpu']:.4f}")


if __name__ == "__main__":
    main()
